"""Uniform vs score-driven selection: time-to-accuracy + scoring cost
(DESIGN.md §11).

Runs the stacked-block toy model (``repro.models.toy`` — scalar +
stacked leaf kinds) through the ``Federation`` facade at the paper's
25%/50% train fractions, once per selection strategy:

* ``uniform`` — the paper's random-subset baseline (scoring OFF: the
  round step compiles the pre-scoring trace, no telemetry anywhere);
* ``score_weighted`` — the paper's future-work variant: Gumbel top-k
  over live per-unit gradient-norm EMAs (scoring ON: the state pytree
  threads through the compiled round step, telemetry rides the
  metrics);
* ``depth_dropout`` / ``successive`` — the related-work schedules
  (Guo et al. 2023 / Pfeiffer et al. 2023), recorded for the curve
  trajectory (no gate).

Per strategy the bench records the eval-loss curve and the round count
to a shared target (1.02x the weaker of uniform/score_weighted's best
— both curves can reach it, the race is on rounds), plus the per-round
wall time of the compiled step.  Correctness gates (what CI relies
on): the scoring-OFF metrics must carry no telemetry (the stateless
trace is the pre-scoring trace) and losses must stay finite; the full
mode (the committed artifact) additionally gates that score_weighted
reaches the target in <= uniform's rounds at 25% and that the
scoring-OFF wall time sits within 5% of the verbatim pre-scoring
oracle.  (The shared target is always reachable by construction, so
there is no reached-at-all gate.)

Writes BENCH_selection.json next to BENCH_round_step.json /
BENCH_async.json (EXPERIMENTS.md §Selection).  ``--smoke`` is the
CI-gate variant (tiny model, fewer rounds, same JSON shape).

    PYTHONPATH=src python -m benchmarks.selection_bench [--smoke]
        [--out BENCH_selection.json]
"""
from __future__ import annotations

import argparse
import json
import platform

import jax
import jax.numpy as jnp
import numpy as np

from .common import timed_min
from repro.core import FLConfig, Federation, build_round_step
from repro.models.toy import (init_toy_mlp, toy_apply, toy_batches,
                              toy_loss, toy_units)

FULL = dict(n_blocks=10, d=32, hidden=64, out=8, n_clients=8, steps=2,
            batch=8, rounds=40, lr=2e-2, score_ema=0.7, n_eval=64, reps=20)
SMOKE = dict(n_blocks=8, d=16, hidden=32, out=4, n_clients=4, steps=2,
             batch=4, rounds=12, lr=2e-2, score_ema=0.7, n_eval=32, reps=2)

STRATEGIES = ("uniform", "score_weighted", "depth_dropout", "successive")


def _setup(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    params = init_toy_mlp(key, n_blocks=cfg["n_blocks"], d=cfg["d"],
                          hidden=cfg["hidden"], out=cfg["out"])
    assign = toy_units(params)
    batches = toy_batches(jax.random.fold_in(key, 1),
                          n_clients=cfg["n_clients"], steps=cfg["steps"],
                          batch=cfg["batch"], d=cfg["d"], out=cfg["out"])
    ek = jax.random.fold_in(key, 2)
    ex = jax.random.normal(jax.random.fold_in(ek, 0),
                           (cfg["n_eval"], cfg["d"]))
    ey = jax.random.normal(jax.random.fold_in(ek, 1),
                           (cfg["n_eval"], cfg["out"]))

    @jax.jit
    def eval_loss(p):
        return jnp.mean(jnp.square(toy_apply(p, ex) - ey))

    return params, assign, batches, eval_loss


def _fl(cfg, strategy, fraction):
    return FLConfig(n_clients=cfg["n_clients"], train_fraction=fraction,
                    strategy=strategy, lr=cfg["lr"], fused_agg="off",
                    score_ema=cfg["score_ema"])


def run_curve(cfg, *, strategy, fraction, seed=0) -> dict:
    params, assign, batches, eval_loss = _setup(cfg)
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=_fl(cfg, strategy, fraction), seed=seed,
                     eval_fn=eval_loss)
    fed.server.run(cfg["rounds"], lambda r: batches)
    losses = [float(r.eval_metric) for r in fed.history]
    row = {"losses": losses, "best_loss": float(min(losses)),
           "scoring": fed.server.sel_state is not None}
    if fed.server.sel_state is not None:
        st = fed.server.sel_state
        row["state"] = {"round": int(st.round),
                        "counts_total": float(np.asarray(st.counts).sum()),
                        "scores_max": float(np.asarray(st.scores).max())}
    return row


def rounds_to_target(losses, target):
    best = float("inf")
    for i, l in enumerate(losses):
        best = min(best, l)
        if best <= target:
            return i + 1
    return None


def _oracle_stateless_step(assign, fl):
    """Verbatim pre-scoring (PR 1-4) stateless masked round step — the
    wall-time oracle for the scoring-OFF acceptance gate.  The scored
    engine must compile this exact program when scoring is off (the
    trace-identity gate asserts no telemetry leaked; the stateless
    bit-exactness tests assert the numerics), so its wall time is the
    regression baseline."""
    from repro.core.aggregation import masked_fedavg
    from repro.core.client import local_update
    from repro.core.masking import mask_tree
    from repro.core.strategies import SelectionContext, resolve_strategy
    strat = resolve_strategy(fl.strategy, fl.synchronized)
    ctx = SelectionContext(n_clients=fl.n_clients, n_units=assign.n_units,
                           n_train=fl.resolve_n_train(assign.n_units))

    def round_step(global_params, client_batches, weights, round_key):
        sel = strat.select(round_key, ctx)

        def one_client(sel_row, batches):
            mask = mask_tree(assign, sel_row, global_params)
            return local_update(toy_loss, global_params, mask, batches,
                                lr=fl.lr)

        deltas, metrics = jax.vmap(one_client)(sel, client_batches)
        new_params = masked_fedavg(global_params, deltas, sel, weights,
                                   assign)
        return new_params, {"loss_mean": metrics["loss_mean"].mean(),
                            "sel": sel}

    return round_step


def bench_wall(cfg, fraction) -> dict:
    """Per-round wall time: scoring OFF (uniform through the scored
    engine) vs the verbatim pre-scoring oracle — the acceptance gate:
    no scoring-off regression > 5% — and vs scoring ON (score_weighted
    + live state + telemetry; overhead recorded honestly, no gate: on
    a CPU-host toy model the extra gumbel/sort/accumulate ops sit in
    measurement noise).  Also asserts the OFF trace carries no
    telemetry."""
    params, assign, batches, _ = _setup(cfg)
    weights = jnp.ones((cfg["n_clients"],), jnp.float32)
    rk = jax.random.PRNGKey(42)
    reps, warmup = cfg["reps"], 2

    fl_off = _fl(cfg, "uniform", fraction)
    off = jax.jit(build_round_step(toy_loss, assign, fl_off))
    t_off, (_, m_off) = timed_min(off, params, batches, weights, rk,
                                  reps=reps, warmup=warmup)

    oracle = jax.jit(_oracle_stateless_step(assign, fl_off))
    t_oracle, _ = timed_min(oracle, params, batches, weights, rk,
                            reps=reps, warmup=warmup)

    from repro.core import SelectionContext, get_strategy
    strat = get_strategy("score_weighted")
    state = strat.init_state(SelectionContext(
        n_clients=cfg["n_clients"], n_units=assign.n_units, n_train=1))
    on = jax.jit(build_round_step(
        toy_loss, assign, _fl(cfg, "score_weighted", fraction)))
    t_on, (_, m_on) = timed_min(on, params, batches, weights, rk, state,
                                reps=reps, warmup=warmup)
    return {"wall_s_scoring_off": t_off,
            "wall_s_pre_scoring_oracle": t_oracle,
            "wall_s_scoring_on": t_on,
            "scoring_off_regression": t_off / t_oracle - 1.0,
            "scoring_on_overhead": t_on / t_off - 1.0,
            "off_trace_has_no_telemetry": "unit_sqnorm" not in m_off,
            "on_trace_has_telemetry": "unit_sqnorm" in m_on}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale run (tiny model, fewer rounds)")
    ap.add_argument("--out", default="BENCH_selection.json")
    ap.add_argument("--fractions", type=float, nargs="+",
                    default=[0.25, 0.50])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    cfg = SMOKE if args.smoke else FULL

    results, failures = {}, []
    for frac in args.fractions:
        curves = {s: run_curve(cfg, strategy=s, fraction=frac,
                               seed=args.seed) for s in STRATEGIES}
        # shared target: just above the weaker head-to-head variant's
        # best loss, so both curves can reach it — the race is on rounds
        target = 1.02 * max(curves["uniform"]["best_loss"],
                            curves["score_weighted"]["best_loss"])
        r_uni = rounds_to_target(curves["uniform"]["losses"], target)
        r_sco = rounds_to_target(curves["score_weighted"]["losses"], target)
        wall = bench_wall(cfg, frac)
        row = {"curves": curves, "target_loss": float(target),
               "rounds_uniform": r_uni, "rounds_score_weighted": r_sco,
               "wall": wall}
        results[f"{frac:.2f}"] = row
        print(f"frac={frac:.2f} target={target:.4f} "
              f"rounds: uniform={r_uni} score_weighted={r_sco} | "
              f"wall oracle={wall['wall_s_pre_scoring_oracle']*1e3:.2f}ms "
              f"off={wall['wall_s_scoring_off']*1e3:.2f}ms "
              f"({wall['scoring_off_regression']*100:+.1f}%) "
              f"on={wall['wall_s_scoring_on']*1e3:.2f}ms "
              f"({wall['scoring_on_overhead']*100:+.1f}%)")
        # sanity gates (both modes): finite curves, scored run actually
        # scored, and the stateless trace is the pre-scoring trace
        for s, c in curves.items():
            if not all(np.isfinite(c["losses"])):
                failures.append(f"non-finite losses: {s} at frac={frac}")
        if not wall["off_trace_has_no_telemetry"]:
            failures.append(f"stateless trace leaked telemetry at "
                            f"frac={frac}")
        if not curves["score_weighted"]["scoring"]:
            failures.append(f"score_weighted did not engage the scored "
                            f"engine at frac={frac}")

    report = {
        "bench": "selection",
        "mode": "smoke" if args.smoke else "full",
        "model": cfg,
        "strategies": list(STRATEGIES),
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "results": results,
    }
    at25 = results.get("0.25")
    if at25 is not None:
        ru, rs = at25["rounds_uniform"], at25["rounds_score_weighted"]
        report["scored_wins_rounds_at_25"] = (
            rs is not None and (ru is None or rs <= ru))
        report["scoring_off_regression_at_25"] = \
            at25["wall"]["scoring_off_regression"]
        report["scoring_on_overhead_at_25"] = \
            at25["wall"]["scoring_on_overhead"]
        # acceptance gates of the committed (full-mode) artifact; the
        # smoke run records them but only fails on the sanity gates —
        # tiny-model round counts and CI wall clocks are too noisy
        if not args.smoke:
            if not report["scored_wins_rounds_at_25"]:
                failures.append("score_weighted needed more rounds than "
                                "uniform at frac=0.25")
            if at25["wall"]["scoring_off_regression"] > 0.05:
                failures.append(
                    f"scoring-off wall-time regression at 25% is "
                    f"{at25['wall']['scoring_off_regression']*100:.1f}% "
                    f"> 5% vs the pre-scoring oracle")
    report["sanity_ok"] = not failures
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if failures:
        raise SystemExit("selection bench gates FAILED: " +
                         "; ".join(failures))
    return report


if __name__ == "__main__":
    main()
