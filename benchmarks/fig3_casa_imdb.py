"""Paper Fig 3: the technique on the other two domains —
(a) CASA HAR LSTM, Non-IID homes; (b) IMDB sentiment CNN-LSTM, IID."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import FLConfig, Federation, ModelSpec
from repro.data import FederatedLoader, casa_like, iid_partition, imdb_like
from repro.models import paper_models as pm
from .common import csv_row, run_rounds


def _run_casa(n_train, rounds, n_homes):
    homes = casa_like(n_homes, key=0, min_samples=60, max_samples=240)

    def loss_fn(p, batch):
        return pm.xent_loss(pm.casa_apply(p, batch["x"]), batch["y"]), {}

    spec = ModelSpec(name="casa", init_params=pm.init_casa,
                     loss_fn=loss_fn, unit_order=pm.casa_units)
    loader = FederatedLoader([{"x": x, "y": y} for x, y in homes],
                             batch_size=16, steps_per_round=2)
    xs = np.concatenate([x[:20] for x, _ in homes])
    ys = np.concatenate([y[:20] for _, y in homes])
    xt, yt = jnp.asarray(xs), jnp.asarray(ys)
    fl = FLConfig(n_clients=n_homes, n_train_units=n_train, lr=3e-3)
    fed = Federation.from_config(
        spec, fl, data=loader,
        eval_fn=lambda p: pm.accuracy(pm.casa_apply(p, xt), yt))
    hist = run_rounds(fed, rounds)
    return [h.eval_metric for h in hist]


def _run_imdb(n_train, rounds, clients, n_data):
    x, y = imdb_like(n_data, key=0)

    def loss_fn(p, batch):
        return pm.xent_loss(pm.imdb_apply(p, batch["x"]), batch["y"]), {}

    spec = ModelSpec(name="imdb", init_params=pm.init_imdb,
                     loss_fn=loss_fn, unit_order=pm.imdb_units)
    shards = iid_partition(n_data, clients, key=1)
    loader = FederatedLoader([{"x": x[s], "y": y[s]} for s in shards],
                             batch_size=16, steps_per_round=2)
    xt, yt = imdb_like(256, key=9)
    xt, yt = jnp.asarray(xt), jnp.asarray(yt)
    fl = FLConfig(n_clients=clients, n_train_units=n_train, lr=3e-3)
    fed = Federation.from_config(
        spec, fl, data=loader,
        eval_fn=lambda p: pm.accuracy(pm.imdb_apply(p, xt), yt))
    hist = run_rounds(fed, rounds)
    return [h.eval_metric for h in hist]


def run(fast: bool = True):
    t0 = time.perf_counter()
    rounds = 5 if fast else 30
    homes = 6 if fast else 10
    print("# Fig 3a (CASA, Non-IID homes): layers of 6, final accuracy")
    casa_final = {}
    for n in ((2, 6) if fast else (2, 3, 4, 6)):
        accs = _run_casa(n, rounds, homes)
        casa_final[n] = accs[-1]
        print(f"casa,{n},{accs[-1]:.3f}," + "|".join(
            f"{a:.3f}" for a in accs))
    print("# Fig 3b (IMDB, IID): layers of 4, final accuracy")
    imdb_final = {}
    for n in ((2, 4) if fast else (1, 2, 3, 4)):
        accs = _run_imdb(n, rounds, 4 if fast else 10,
                         400 if fast else 4000)
        imdb_final[n] = accs[-1]
        print(f"imdb,{n},{accs[-1]:.3f}," + "|".join(
            f"{a:.3f}" for a in accs))
    gap_c = casa_final[max(casa_final)] - casa_final[min(casa_final)]
    gap_i = imdb_final[max(imdb_final)] - imdb_final[min(imdb_final)]
    csv_row("fig3_casa_imdb", (time.perf_counter() - t0) * 1e6,
            f"casa_partial_gap={gap_c:.3f} imdb_partial_gap={gap_i:.3f}")


if __name__ == "__main__":
    run()
