"""Uplink codec bench: convergence vs bytes on the wire (DESIGN.md §16).

Runs the paper's VGG16 (reduced width) on CIFAR-shaped data with the
uplink codec axis over the packed trained-slot deltas — fp32 (``none``),
``qint8``/``qint4`` stochastic-rounding quantization and ``topk_ef``
top-k sparsification with error feedback — at the paper's freeze
settings, next to the Table-4 byte columns the codecs shrink further.

Three acceptance gates ride in the JSON (what CI relies on):

* ``none_bitwise_equal`` — configuring ``codec="none"`` reproduces the
  pre-codec run BITWISE on all three round paths (sync packed, buffered
  async, chunked cohort): the codec seam compiles to nothing when off.
  This is the only gate ``--smoke`` fails on by itself.
* ``claimed_equals_encoded`` — every round's billed uplink equals the
  encoded wire bytes of what actually crossed the WAN, across
  {hub, hierarchical} x {sync, async, cohort} (hierarchical bills the
  per-edge selection *union* at encoded width).
* ``qint8_ok`` (full mode) — at 25% freeze, qint8 matches the fp32
  run's accuracy while shipping >= 3.5x fewer remaining uplink bytes
  (the byte-ratio half of the gate is deterministic and checked in
  smoke mode too).

Writes BENCH_codec.json (EXPERIMENTS.md §Codec).  ``--smoke`` is the
CI-gate variant (tiny data, fewer rounds, same JSON shape).

    PYTHONPATH=src python -m benchmarks.codec_bench [--smoke]
        [--out BENCH_codec.json]
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import platform

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FLConfig, Federation, ModelSpec, ServerHook,
                        comm, encoded_wire_bytes, get_codec, slot_plan)
from repro.data import FederatedLoader, cifar_like, iid_partition
from repro.models import paper_models as pm

FULL = dict(n_clients=8, rounds=8, width=0.125, n_data=256, n_eval=128,
            batch=4, steps=2, lr=2e-3, fractions=[0.25, 0.50])
SMOKE = dict(n_clients=4, rounds=3, width=0.125, n_data=96, n_eval=64,
             batch=4, steps=2, lr=2e-3, fractions=[0.25])

CODECS = ["none", "qint8", "qint4", "topk_ef"]
PATHS = ["sync", "async", "cohort"]


def vgg_loss(p, batch):
    return pm.xent_loss(pm.vgg16_apply(p, batch["x"]), batch["y"]), {}


def _setup(cfg):
    spec = ModelSpec(
        name="vgg16",
        init_params=functools.partial(pm.init_vgg16,
                                      width_mult=cfg["width"]),
        loss_fn=vgg_loss, unit_order=pm.vgg16_units)
    x, y = cifar_like(cfg["n_data"], key=0)
    shards = iid_partition(cfg["n_data"], cfg["n_clients"], key=1)
    loader = FederatedLoader([{"x": x[s], "y": y[s]} for s in shards],
                             batch_size=cfg["batch"],
                             steps_per_round=cfg["steps"])
    ex, ey = cifar_like(cfg["n_eval"], key=7)
    ex, ey = jnp.asarray(ex), jnp.asarray(ey)

    @jax.jit
    def accuracy(params):
        return (pm.vgg16_apply(params, ex).argmax(-1) == ey).mean()

    return spec, loader, accuracy


def _fl(cfg, path, frac, codec="none", topo="hub", **extra):
    kw = dict(n_clients=cfg["n_clients"], train_fraction=frac,
              lr=cfg["lr"], fused_agg="off", packed=True,
              topology=topo, codec=codec, **extra)
    if path == "async":
        kw.update(async_buffer=cfg["n_clients"], staleness="constant",
                  client_delay_dist="none")
    elif path == "cohort":
        kw.update(cohort_chunk=2, n_registered=cfg["n_clients"])
    return FLConfig(**kw)


def _run(cfg, fl, seed=0, hooks=None):
    spec, loader, accuracy = _setup(cfg)
    fed = Federation.from_config(spec, fl, data=loader, seed=seed,
                                 eval_fn=accuracy, hooks=hooks or [])
    fed.fit(cfg["rounds"])
    return fed


def _leaves(fed):
    return [np.asarray(x)
            for x in jax.tree_util.tree_leaves(fed.server.params)]


def _bitequal(a, b):
    return all(np.array_equal(x, y) for x, y in zip(_leaves(a),
                                                    _leaves(b)))


class _Entries(ServerHook):
    """Grabs the buffered-async flush composition (entry selections +
    the fleet ids behind them) — the wire traffic the accounting bills."""

    def __init__(self):
        self.rows = []

    def on_round_end(self, server, record, metrics):
        if metrics is not None and "entry_sel" in metrics:
            self.rows.append((np.asarray(metrics["entry_sel"]),
                              np.asarray(metrics["entry_clients"],
                                         np.int64)))


def _encoded(fed, fl, codec, wire_sel):
    """Ground-truth encoded bytes of a wire-selection matrix: the slot
    plan at FULL width (a hierarchical union can exceed n_slots) fed to
    the codec's per-row byte formula."""
    assign = fed.server.assign
    params = fed.server.global_params()
    _, valid = jax.vmap(
        lambda s: slot_plan(assign, s, assign.n_units, params)
    )(jnp.asarray(wire_sel, jnp.float32))
    return encoded_wire_bytes(codec, assign, params, valid, fl)


def claimed_vs_encoded(cfg, path, topo, seed=0):
    """One short qint8 fit on (path, topo); every round's billed uplink
    must equal the encoded bytes of what crossed that topology's WAN."""
    codec = get_codec("qint8")
    fl = _fl(cfg, path, cfg["fractions"][0], codec="qint8", topo=topo)
    cap = _Entries()
    fed = _run(cfg, fl, seed=seed, hooks=[cap])
    mem = comm.edge_membership(fl.n_clients, fl.resolve_n_edges()) \
        if topo == "hierarchical" else None
    worst = 0.0
    for r, rec in enumerate(fed.server.history):
        if path == "async":
            entry_sel, ids = cap.rows[r]
            wire = (mem[:, ids] @ entry_sel > 0).astype(np.float32) \
                if topo == "hierarchical" else entry_sel
        else:
            sel = np.asarray(fed.server.sel_history[r])
            wire = (mem @ sel > 0).astype(np.float32) \
                if topo == "hierarchical" else sel
        worst = max(worst, abs(rec.uplink_bytes
                               - _encoded(fed, fl, codec, wire)))
    return {"path": path, "topology": topo,
            "rounds": len(fed.server.history),
            "max_abs_diff_bytes": worst, "exact": worst == 0.0}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale run (tiny model/data, fewer rounds)")
    ap.add_argument("--out", default="BENCH_codec.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    cfg = SMOKE if args.smoke else FULL
    full_mode = not args.smoke

    failures, smoke_failures = [], []

    # -- gate 1: codec "none" is the pre-codec run, bitwise, per path --
    bitwise = {}
    for path in PATHS:
        base = _run(cfg, _fl(cfg, path, cfg["fractions"][0]),
                    seed=args.seed)
        off = _run(cfg, _fl(cfg, path, cfg["fractions"][0],
                            codec="none"), seed=args.seed)
        ok = _bitequal(base, off) and all(
            a.loss == b.loss for a, b in zip(base.server.history,
                                             off.server.history))
        bitwise[path] = ok
        print(f"none-bitwise {path:<6} {'OK' if ok else 'FAIL'}")
        if not ok:
            smoke_failures.append(f"codec 'none' not bitwise on {path}")

    # -- codec ladder: accuracy trajectory vs remaining uplink bytes --
    curves = {}
    for frac in cfg["fractions"]:
        row = {}
        for name in CODECS:
            fed = _run(cfg, _fl(cfg, "sync", frac, codec=name,
                                codec_topk=0.25), seed=args.seed)
            s = fed.comm_summary()
            accs = [r.eval_metric for r in fed.server.history]
            row[name] = {
                "accs": [float(a) for a in accs],
                "final_acc": float(accs[-1]),
                "best_acc": float(max(accs)),
                "avg_uplink_bytes": s["avg_uplink_bytes"],
                "total_uplink_bytes": s["total_uplink_bytes"],
                "reduction_vs_full": s["reduction_vs_full"],
                "finite": bool(all(np.isfinite(x).all()
                                   for x in _leaves(fed))),
            }
            if not row[name]["finite"]:
                smoke_failures.append(f"non-finite params: {name}@{frac}")
        for name in CODECS[1:]:
            row[name]["bytes_ratio_vs_fp32"] = (
                row["none"]["avg_uplink_bytes"]
                / row[name]["avg_uplink_bytes"])
            print(f"frac={frac:.2f} {name:<8} "
                  f"acc={row[name]['best_acc']:.3f} "
                  f"(fp32 {row['none']['best_acc']:.3f}) "
                  f"bytes/fp32=1/{row[name]['bytes_ratio_vs_fp32']:.2f}")
        curves[f"{frac:.2f}"] = row

    # gate 2a (deterministic, smoke too): qint8 ships >= 3.5x fewer
    # remaining uplink bytes than fp32 at the first freeze setting
    q = curves[f"{cfg['fractions'][0]:.2f}"]
    ratio = q["qint8"]["bytes_ratio_vs_fp32"]
    if ratio < 3.5:
        smoke_failures.append(
            f"qint8 byte ratio {ratio:.2f}x < 3.5x vs fp32")
    # gate 2b (full mode): ...while matching fp32 accuracy
    acc_ok = q["qint8"]["best_acc"] + 0.02 >= q["none"]["best_acc"]
    if full_mode and not acc_ok:
        failures.append(
            f"qint8 best acc {q['qint8']['best_acc']:.3f} below fp32 "
            f"target {q['none']['best_acc']:.3f}")

    # -- gate 3: claimed bytes == encoded wire bytes, all paths/topos --
    billing = []
    for topo in ("hub", "hierarchical"):
        for path in PATHS:
            res = claimed_vs_encoded(cfg, path, topo, seed=args.seed)
            billing.append(res)
            print(f"claimed==encoded {topo:<13} {path:<6} "
                  f"{'OK' if res['exact'] else 'FAIL'}")
            if not res["exact"]:
                smoke_failures.append(
                    f"billed uplink != encoded bytes on "
                    f"{topo}/{path} (off by "
                    f"{res['max_abs_diff_bytes']:.0f}B)")

    report = {
        "bench": "codec",
        "mode": "smoke" if args.smoke else "full",
        "model": cfg,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "none_bitwise_equal": bitwise,
        "curves": curves,
        "qint8_bytes_ratio_vs_fp32": ratio,
        "qint8_acc_matches_fp32": acc_ok,
        "billing": billing,
        "claimed_equals_encoded": all(b["exact"] for b in billing),
        "qint8_ok": ratio >= 3.5 and acc_ok,
        "sanity_ok": not (failures + smoke_failures),
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if smoke_failures or (full_mode and failures):
        raise SystemExit("codec bench sanity FAILED: " +
                         "; ".join(smoke_failures + failures))
    return report


if __name__ == "__main__":
    main()
