"""Continuous-batching vs static-batch serving at mixed generation
lengths (DESIGN.md §12, EXPERIMENTS.md §Serving).

One mixed workload — uniform prompt length, generation lengths spread
over a range — served two ways with the same greedy sampling:

* **static** — fixed batches of ``n_slots`` in submission order; every
  batch decodes to its LONGEST request, so short requests burn wasted
  decode steps and the tail request waits for every earlier batch;
* **continuous** — the paged engine: a slot frees the moment its
  request finishes and the next request admits mid-flight, so decode
  steps track useful tokens.

Both paths are warmed up (compile excluded) and produce per-request
token streams; the bench gates that the streams are identical (the
engine's bitwise contract, here end-to-end) and — full mode — that
continuous throughput beats static.  Records tokens/sec, p50/p99
request latency, decode-step counts, and the wasted-step accounting to
``BENCH_serve.json`` next to BENCH_round_step / BENCH_async /
BENCH_selection.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
        [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import get_model
from repro.serve.engine import DecodeEngine, ServeConfig

# full mode scales the reduced config back up until device compute per
# decode step dominates Python dispatch — the regime the continuous-vs-
# static comparison is about (at pure-toy sizes both paths measure the
# dispatcher, and the static loop's fewer dispatches win on noise)
FULL = dict(arch="qwen3-1.7b", n_slots=8, n_req=24, prompt_len=16,
            gen_min=8, gen_max=64, max_len=80, page_size=16,
            model=dict(d_model=512, n_layers=8, n_heads=8, n_kv_heads=4,
                       head_dim=64, d_ff=1536, vocab=4096))
SMOKE = dict(arch="qwen3-1.7b", n_slots=2, n_req=4, prompt_len=16,
             gen_min=3, gen_max=6, max_len=32, page_size=16, model=None)


def make_workload(cfg, bench, seed=0):
    key = jax.random.PRNGKey(seed)
    prompts = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 1), (bench["n_req"], bench["prompt_len"]),
        0, cfg.vocab))
    span = bench["gen_max"] - bench["gen_min"] + 1
    # deterministic spread, worst-case-ish for static batching: long and
    # short generations interleave inside every chunk
    gens = [bench["gen_min"] + (i * 5) % span for i in range(bench["n_req"])]
    return prompts, gens


class StaticServer:
    """Fixed-batch serving: chunks of n_slots decode to the chunk's max
    generation length.  Jits once, reused across chunks and runs."""

    def __init__(self, cfg, params, n_slots, max_len):
        self.model = get_model(cfg)
        self.params = params
        self.n_slots = n_slots
        model = self.model
        kw = {"attn_impl": "reference"} if cfg.family != "ssm" else {}

        def prefill_fn(params, tokens):
            logits, cache = model.prefill(params, tokens, max_len=max_len,
                                          last_only=True, **kw)
            row = logits[:, -1]
            return jnp.argmax(row, -1).astype(jnp.int32), cache

        def decode_fn(params, cache, token):
            logits, cache = model.decode_step(params, cache, token)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)

    def run(self, prompts, gens):
        """Returns (streams {i: np.ndarray}, finish_time_per_req, counters)."""
        t0 = time.perf_counter()
        streams, t_finish = {}, {}
        decode_steps = wasted = 0
        for c0 in range(0, len(gens), self.n_slots):
            ids = list(range(c0, min(c0 + self.n_slots, len(gens))))
            pad = self.n_slots - len(ids)           # keep batch shape static
            batch = np.concatenate([prompts[ids]] +
                                   [prompts[ids[-1:]]] * pad)
            g_max = max(gens[i] for i in ids)
            tok, cache = self._prefill(self.params, jnp.asarray(batch))
            toks = [tok]
            for _ in range(g_max - 1):
                tok, cache = self._decode(self.params, cache,
                                          tok[:, None])
                toks.append(tok)
            jax.block_until_ready(tok)
            decode_steps += g_max - 1
            out = np.stack([np.asarray(t) for t in toks], axis=1)
            now = time.perf_counter() - t0
            for j, i in enumerate(ids):
                streams[i] = out[j, :gens[i]].astype(np.int32)
                t_finish[i] = now
                wasted += g_max - gens[i]
            wasted += pad * g_max
        wall = time.perf_counter() - t0
        return streams, t_finish, {"wall_s": wall,
                                   "decode_steps": decode_steps,
                                   "wasted_token_steps": wasted}


def run_static(cfg, params, bench, prompts, gens, max_len):
    srv = StaticServer(cfg, params, bench["n_slots"], max_len)
    srv.run(prompts[:bench["n_slots"]], gens[:bench["n_slots"]])  # warm-up
    streams, t_fin, c = srv.run(prompts, gens)
    total = int(sum(gens))
    lat = np.asarray([t_fin[i] for i in range(len(gens))])
    return streams, {
        "wall_s": c["wall_s"],
        "tokens_per_sec": total / c["wall_s"],
        "decode_steps": c["decode_steps"],
        "wasted_token_steps": c["wasted_token_steps"],
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
    }


def run_continuous(cfg, params, bench, prompts, gens):
    eng = DecodeEngine(cfg, params, ServeConfig(
        n_slots=bench["n_slots"], max_len=bench["max_len"],
        page_size=bench["page_size"]))
    # warm-up: compile the decode step plus prefill/commit for every
    # admission-group size the mixed workload can produce (1..n_slots)
    for g in range(1, bench["n_slots"] + 1):
        for i in range(g):
            eng.submit(prompts[i], 2)
        eng.run()
    warm_rids = set(range(eng._next_rid))
    warm_steps = eng.n_decode_steps

    t0 = time.perf_counter()
    rids = [eng.submit(prompts[i], gens[i]) for i in range(len(gens))]
    results = eng.run()
    wall = time.perf_counter() - t0
    streams = {i: results[r] for i, r in enumerate(rids)}
    reqs = [eng.scheduler.requests[r] for r in rids]
    lat = np.asarray([r.t_finish - r.t_submit for r in reqs])
    total = int(sum(gens))
    st = eng.stats()
    return streams, {
        "wall_s": wall,
        "tokens_per_sec": total / wall,
        "decode_steps": st["n_decode_steps"] - warm_steps,
        "n_preemptions": st["n_preemptions"],
        "peak_pages": st["peak_pages"],
        "decode_compiles": eng.decode_cache_size,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
    }, warm_rids


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale run (tiny workload, same JSON shape)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    bench = dict(SMOKE if args.smoke else FULL)

    cfg = get_config(bench["arch"]).reduced()
    if bench["model"]:
        cfg = cfg.replace(**bench["model"])
    params = get_model(cfg).init_params(jax.random.PRNGKey(args.seed))
    prompts, gens = make_workload(cfg, bench, seed=args.seed)
    total = int(sum(gens))

    s_streams, s_row = run_static(cfg, params, bench, prompts, gens,
                                  max_len=bench["max_len"])
    c_streams, c_row, _ = run_continuous(cfg, params, bench, prompts, gens)

    streams_equal = all(np.array_equal(s_streams[i], c_streams[i])
                        for i in range(len(gens)))
    speedup = c_row["tokens_per_sec"] / s_row["tokens_per_sec"]
    print(f"static:     {s_row['tokens_per_sec']:8.1f} tok/s  "
          f"{s_row['decode_steps']} decode steps  "
          f"({s_row['wasted_token_steps']} wasted token-steps)  "
          f"p50={s_row['latency_p50_s']:.2f}s p99={s_row['latency_p99_s']:.2f}s")
    print(f"continuous: {c_row['tokens_per_sec']:8.1f} tok/s  "
          f"{c_row['decode_steps']} decode steps  "
          f"({c_row['n_preemptions']} preemptions)  "
          f"p50={c_row['latency_p50_s']:.2f}s p99={c_row['latency_p99_s']:.2f}s")
    print(f"speedup x{speedup:.2f}  streams equal: {streams_equal}")

    failures = []
    if not streams_equal:
        failures.append("continuous streams diverge from static")
    if c_row["decode_compiles"] != 1:
        failures.append(f"decode step compiled "
                        f"{c_row['decode_compiles']}x (recompile-free "
                        f"contract broken)")
    if not np.isfinite([s_row["tokens_per_sec"],
                        c_row["tokens_per_sec"]]).all():
        failures.append("non-finite throughput")
    # acceptance gate of the committed (full-mode) artifact: continuous
    # must beat static on useful tokens/sec at mixed gen lengths.  The
    # smoke run records the ratio but does not gate it — CI wall clocks
    # on a 4-request workload are noise.
    if not args.smoke and speedup < 1.0:
        failures.append(f"continuous ({c_row['tokens_per_sec']:.1f} tok/s) "
                        f"did not beat static "
                        f"({s_row['tokens_per_sec']:.1f} tok/s)")

    report = {
        "bench": "serve",
        "mode": "smoke" if args.smoke else "full",
        "workload": {**bench, "gens": gens, "total_tokens": total},
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "results": {"static": s_row, "continuous": c_row},
        "continuous_over_static_speedup": speedup,
        "streams_equal": streams_equal,
        "sanity_ok": not failures,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if failures:
        raise SystemExit("serve bench gates FAILED: " + "; ".join(failures))
    return report


if __name__ == "__main__":
    main()
