"""Paper Tables 5-6: client resource needs vs number of trained layers.

We report the analytic training-state memory model (the quantity the
Jetson ran out of) + the compiled executable's temp-buffer bytes per
setting: params + trained-unit gradients + trained-unit Adam moments +
activations.  The paper's observation reproduced: memory falls with the
trained fraction, enabling constrained clients (their 2 GB Jetson could
run 4-10 layers but crashed on 14)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masking import build_units_flat, unit_param_counts
from repro.data import cifar_like
from repro.models import paper_models as pm
from .common import csv_row
from .table3_time import make_static_step
from repro.optim.masked import adam_init


def run(fast: bool = True):
    t0 = time.perf_counter()
    width = 0.5
    bs = 4                      # the paper's Jetson batch size
    params = pm.init_vgg16(jax.random.PRNGKey(0), width_mult=width)
    units = pm.vgg16_units(params)
    assign = build_units_flat(params, units)
    counts = unit_param_counts(assign, params)
    order = {k: i for i, k in enumerate(units)}
    total = counts.sum()
    x, y = cifar_like(bs, key=0)
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    print(f"# Table 5/6 reproduction (lighter VGG16 w={width}, batch {bs} "
          "— the paper's Jetson setup)")
    print("# layers, analytic_state_MB, compiled_temp_MB, "
          "state_vs_full")
    rows = {}
    for n in (4, 7, 10, 14):
        trainable = units[-n:]
        tsel = np.zeros(len(units))
        for k in trainable:
            tsel[order[k]] = 1
        trained_params = float(tsel @ counts)
        # params(4B) + grads(4B, trained) + adam m+v (8B, trained)
        analytic = 4 * total + 12 * trained_params
        train_p = {k: params[k] for k in trainable}
        step = make_static_step(params, trainable, batch)
        comp = step.lower(train_p, adam_init(train_p), batch).compile()
        ma = comp.memory_analysis()
        temp = float(getattr(ma, "temp_size_in_bytes", 0))
        rows[n] = (analytic, temp)
    full_state = rows[14][0]
    for n in (4, 7, 10, 14):
        analytic, temp = rows[n]
        print(f"{n},{analytic/1e6:.1f},{temp/1e6:.1f},"
              f"{analytic/full_state:.3f}")
    csv_row("table5_resources", (time.perf_counter() - t0) * 1e6,
            "training-state bytes fall linearly with trained fraction")


if __name__ == "__main__":
    run()
