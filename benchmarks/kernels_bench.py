"""Kernel microbenches: interpret-mode Pallas vs pure-jnp oracle.

On this CPU host the numbers validate plumbing (the kernel path runs and
matches); TPU wall-times belong to the roofline analysis, not here."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import masked_fedavg
from repro.core.masking import build_units_flat
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_decode.ops import decode_attention
from repro.kernels.masked_agg.ops import build_agg_plan, masked_fedavg_fused
from repro.kernels.rwkv6_scan.ops import wkv
from repro.models import paper_models as pm
from repro.models.attention import attend_reference, decode_attend
from repro.models.linear_scan import chunked_linear_scan
from .common import csv_row, timed


def run(fast: bool = True):
    key = jax.random.PRNGKey(0)
    b, s, h, hd = 1, 256, 4, 64
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))

    dt, o = timed(jax.jit(lambda q, k, v: flash_attention(
        q, k, v, True, 0, 128, 128, True)), q, k, v, reps=2)
    ref = attend_reference(q, k, v, causal=True)
    err = float(jnp.abs(o - ref).max())
    csv_row("kernel_flash_attention_interp", dt * 1e6, f"maxerr={err:.1e}")

    qd = q[:, :1]
    vl = jnp.full((b,), s, jnp.int32)
    dt, od = timed(jax.jit(lambda q, k, v: decode_attention(
        q, k, v, vl, blk_k=128)), qd, k, v, reps=2)
    err = float(jnp.abs(od - decode_attend(qd, k, v, vl)).max())
    csv_row("kernel_flash_decode_interp", dt * 1e6, f"maxerr={err:.1e}")

    r = jax.random.normal(ks[3], (b, s, h, 32))
    ld = -jnp.abs(jax.random.normal(ks[4], (b, s, h, 32)))
    u = jnp.zeros((h, 32))
    vv = jax.random.normal(ks[2], (b, s, h, 32))
    dt, (ow, _) = timed(jax.jit(lambda r, k, v, d: wkv(
        r, k, v, d, u, chunk=16)), r, r, vv, ld, reps=2)
    oc, _ = chunked_linear_scan(r, r, vv, ld, decay_on="k", bonus=u,
                                chunk=16)
    err = float(jnp.abs(ow - oc).max())
    csv_row("kernel_rwkv6_scan_interp", dt * 1e6, f"maxerr={err:.1e}")

    # fused masked FedAvg at realistic paper-model tile counts: the
    # VGG16 reproduction (14 freeze units), 10 clients, 25% selection —
    # so kernel-level and round-level (round_step_bench) numbers land
    # in the same report
    p = pm.init_vgg16(ks[0], width_mult=0.125)
    assign = build_units_flat(p, pm.vgg16_units(p))
    c = 10
    rng = np.random.default_rng(0)
    sel = np.zeros((c, assign.n_units), np.float32)
    n_train = max(1, round(assign.n_units * 0.25))
    for i in range(c):
        sel[i, rng.choice(assign.n_units, n_train, replace=False)] = 1.0
    sel = jnp.asarray(sel)
    w = jnp.ones((c,))
    leaves, treedef = jax.tree_util.tree_flatten(p)
    deltas = jax.tree_util.tree_unflatten(treedef, [
        jax.random.normal(jax.random.fold_in(ks[1], i),
                          (c,) + x.shape) * 0.05
        for i, x in enumerate(leaves)])
    plan = build_agg_plan(assign, p)
    dt, oa = timed(jax.jit(lambda g, d, s, ww: masked_fedavg_fused(
        g, d, s, ww, assign, plan=plan)), p, deltas, sel, w, reps=2)
    ref = masked_fedavg(p, deltas, sel, w, assign)
    err = float(max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))
                    .max() for a, b in
                    zip(jax.tree_util.tree_leaves(oa),
                        jax.tree_util.tree_leaves(ref))))
    csv_row("kernel_masked_agg_interp", dt * 1e6,
            f"tiles={plan.n_rows},units={assign.n_units},"
            f"clients={c},maxerr={err:.1e}")


if __name__ == "__main__":
    run()
