"""Chaos-vs-accuracy bench: fit under injected faults (DESIGN.md §14).

Runs the paper's VGG16 (reduced width) on CIFAR-shaped data at the
paper's 25%/50% freeze settings under a ladder of fault regimes —
clean, zero-rate chaos (every fault named, every rate 0.0), 10% client
crash, 10% crash + 5% NaN corruption, 25% crash + 5% NaN — and records
the accuracy trajectory, the wasted-bytes column (quarantined uploads)
and the quarantine counts per regime.

Two acceptance gates ride in the JSON (what CI relies on):

* ``zero_fault_bitwise_equal`` — the zero-rate chaos run's params are
  BITWISE the clean run's: the compiled-in injection + validation gate
  are exact identities when nothing fires.
* ``resume_bitwise_equal`` — a run with injected server kills
  (``kill:`` fault), auto-restarted from its checkpoint by
  ``run_with_restarts``, reproduces the uninterrupted fit bit-exactly.
* ``quarantine_matches_plan`` — quarantined-update counts equal the
  injector's deterministic corruption plan exactly, per round.

Writes BENCH_faults.json (EXPERIMENTS.md §Faults).  ``--smoke`` is the
CI-gate variant (tiny data, fewer rounds, same JSON shape).

    PYTHONPATH=src python -m benchmarks.faults_bench [--smoke]
        [--out BENCH_faults.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import platform
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Checkpointer, FLConfig, Federation, ModelSpec,
                        ServerHook, run_with_restarts)
from repro.data import FederatedLoader, cifar_like, iid_partition
from repro.models import paper_models as pm

FULL = dict(n_clients=8, rounds=8, width=0.125, n_data=256, n_eval=128,
            batch=4, steps=2, lr=2e-3, kill=0.3)
SMOKE = dict(n_clients=4, rounds=4, width=0.125, n_data=128, n_eval=64,
             batch=4, steps=2, lr=2e-3, kill=0.5)

# the fault ladder: ISSUE acceptance regimes + the bitwise gates' pair
VARIANTS = [
    ("clean", ""),
    ("zero_rate", "crash:0,nan:0"),
    ("crash10", "crash:0.1"),
    ("crash10_nan5", "crash:0.1,nan:0.05"),
    ("crash25_nan5", "crash:0.25,nan:0.05"),
]


def vgg_loss(p, batch):
    return pm.xent_loss(pm.vgg16_apply(p, batch["x"]), batch["y"]), {}


def _setup(cfg):
    spec = ModelSpec(
        name="vgg16",
        init_params=functools.partial(pm.init_vgg16,
                                      width_mult=cfg["width"]),
        loss_fn=vgg_loss, unit_order=pm.vgg16_units)
    x, y = cifar_like(cfg["n_data"], key=0)
    shards = iid_partition(cfg["n_data"], cfg["n_clients"], key=1)
    loader = FederatedLoader([{"x": x[s], "y": y[s]} for s in shards],
                             batch_size=cfg["batch"],
                             steps_per_round=cfg["steps"])
    ex, ey = cifar_like(cfg["n_eval"], key=7)
    ex, ey = jnp.asarray(ex), jnp.asarray(ey)

    @jax.jit
    def accuracy(params):
        return (pm.vgg16_apply(params, ex).argmax(-1) == ey).mean()

    return spec, loader, accuracy


class _QuarantineCount(ServerHook):
    def __init__(self):
        self.count = 0

    def on_round_end(self, server, record, metrics):
        if metrics is not None and "quarantined" in metrics:
            self.count += int((np.asarray(metrics["quarantined"]) > 0)
                              .sum())


def _leaves(fed):
    return [np.asarray(x)
            for x in jax.tree_util.tree_leaves(fed.server.params)]


def _bitequal(a, b):
    return all(np.array_equal(x, y) for x, y in zip(_leaves(a),
                                                    _leaves(b)))


def run_variant(cfg, *, fraction, faults, seed=0):
    spec, loader, accuracy = _setup(cfg)
    fl = FLConfig(n_clients=cfg["n_clients"], train_fraction=fraction,
                  lr=cfg["lr"], fused_agg="off", packed=True,
                  faults=faults)
    quar = _QuarantineCount()
    fed = Federation.from_config(spec, fl, data=loader, seed=seed,
                                 eval_fn=accuracy, hooks=[quar])
    fed.fit(cfg["rounds"])
    injected = 0
    inj = fed.server.fault_injector
    if inj is not None and inj.has_delta:
        injected = sum(
            int((inj.corrupt_plan(r, range(cfg["n_clients"]))["mode"]
                 != 0).sum()) for r in range(cfg["rounds"]))
    accs = [r.eval_metric for r in fed.history]
    return fed, {
        "faults": faults,
        "accs": [float(a) for a in accs],
        "final_acc": float(accs[-1]),
        "finite": bool(all(np.isfinite(x).all() for x in _leaves(fed))),
        "total_wasted_bytes": float(sum(r.wasted_bytes
                                        for r in fed.history)),
        "quarantined": quar.count,
        "injected_corruptions": injected,
    }


def run_resume_gate(cfg, *, fraction, seed=0):
    """Kill-at-any-boundary + auto-resume == uninterrupted, bitwise.
    Both runs share the same crash/NaN chaos (those draws are keyed on
    coordinates, not the restart count); only the kill axis differs."""
    spec, loader, accuracy = _setup(cfg)
    base = "crash:0.1,nan:0.05"
    fl = FLConfig(n_clients=cfg["n_clients"], train_fraction=fraction,
                  lr=cfg["lr"], fused_agg="off", packed=True)
    ref = Federation.from_config(spec, dataclasses.replace(fl, faults=base),
                                 data=loader, seed=seed, eval_fn=accuracy)
    ref.fit(cfg["rounds"])
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck")

        def make(inc):
            return Federation.from_config(
                spec, dataclasses.replace(
                    fl, faults=f"{base},kill:{cfg['kill']}"),
                data=loader, seed=seed, eval_fn=accuracy,
                hooks=[Checkpointer(path, every=1)],
                incarnation=inc)

        fed = run_with_restarts(make, cfg["rounds"], path)
    return {
        "restarts": int(fed.server.fault_injector.incarnation),
        "resume_bitwise_equal": _bitequal(ref, fed),
        "losses_equal": bool(
            len(fed.history) == len(ref.history)
            and all(a.loss == b.loss
                    for a, b in zip(ref.history, fed.history))),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale run (tiny model/data, fewer rounds)")
    ap.add_argument("--out", default="BENCH_faults.json")
    ap.add_argument("--fractions", type=float, nargs="+",
                    default=[0.25, 0.50])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    cfg = SMOKE if args.smoke else FULL

    results, failures = {}, []
    for frac in args.fractions:
        row, feds = {}, {}
        for name, spec in VARIANTS:
            fed, res = run_variant(cfg, fraction=frac, faults=spec,
                                   seed=args.seed)
            row[name] = res
            feds[name] = fed
            print(f"frac={frac:.2f} {name:<13} "
                  f"acc={res['final_acc']:.3f} "
                  f"wasted={res['total_wasted_bytes']/1e3:.1f}kB "
                  f"quarantined={res['quarantined']}"
                  f"/{res['injected_corruptions']}")
            if not res["finite"]:
                failures.append(f"non-finite params: {name}@{frac}")
            if res["quarantined"] != res["injected_corruptions"]:
                failures.append(
                    f"quarantine {res['quarantined']} != injected "
                    f"{res['injected_corruptions']}: {name}@{frac}")
        row["zero_fault_bitwise_equal"] = _bitequal(feds["clean"],
                                                    feds["zero_rate"])
        if not row["zero_fault_bitwise_equal"]:
            failures.append(f"zero-rate chaos not bitwise at {frac}")
        results[f"{frac:.2f}"] = row

    resume = run_resume_gate(cfg, fraction=args.fractions[0],
                             seed=args.seed)
    print(f"resume gate: restarts={resume['restarts']} "
          f"bitwise={resume['resume_bitwise_equal']}")
    if not resume["resume_bitwise_equal"] or not resume["losses_equal"]:
        failures.append("kill+resume diverged from uninterrupted run")

    report = {
        "bench": "faults",
        "mode": "smoke" if args.smoke else "full",
        "model": cfg,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "results": results,
        "resume": resume,
        "sanity_ok": not failures,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if failures:
        raise SystemExit("faults bench sanity FAILED: " +
                         "; ".join(failures))
    return report


if __name__ == "__main__":
    main()
