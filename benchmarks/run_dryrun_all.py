"""Driver: run the full (arch × shape × mesh) dry-run sweep.

Each run needs a fresh process (the 512-fake-device XLA flag binds at
jax init), so this spawns ``python -m repro.launch.dryrun`` per pair and
collects results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.run_dryrun_all \
        [--mesh single|multi|both] [--archs a,b] [--shapes s1,s2]
        [--fl] [--timeout 900]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs.base import list_configs
from repro.launch.shapes import SHAPES, shape_applicable, list_pairs
from repro.configs.base import get_config


def run_one(arch, shape, mesh, extra=(), timeout=900, out="results/dryrun"):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", out, *extra]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    t0 = time.time()
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env)
    dt = time.time() - t0
    ok = r.returncode == 0
    tail = (r.stdout + r.stderr).strip().splitlines()[-12:]
    return ok, dt, tail


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--archs", default=None)
    ap.add_argument("--shapes", default=None)
    ap.add_argument("--fl", action="store_true",
                    help="also lower the FL round for train_4k")
    ap.add_argument("--timeout", type=int, default=900)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = args.archs.split(",") if args.archs else list(list_configs())
    shapes = args.shapes.split(",") if args.shapes else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            ok_app, why = shape_applicable(arch, cfg, SHAPES[shape])
            if not ok_app:
                print(f"SKIP  {arch} x {shape}: {why}")
                # write the skip record so the roofline table shows it
                os.makedirs(args.out, exist_ok=True)
                for mesh in meshes:
                    with open(os.path.join(
                            args.out, f"{arch}_{shape}_{mesh}.json"),
                            "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "mesh": mesh, "skipped": True,
                                   "reason": why}, f)
                continue
            for mesh in meshes:
                path = os.path.join(args.out,
                                    f"{arch}_{shape}_{mesh}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    have = "roofline" in rec if mesh == "single" \
                        else "memory_analysis" in rec
                    if not rec.get("skipped") and have:
                        print(f"HAVE  {arch} x {shape} x {mesh}")
                        continue
                extra = []
                if args.fl and shape == "train_4k":
                    extra = ["--step", "fl_round"]
                if mesh == "multi":
                    # multi-pod proves lowering + memory; the roofline
                    # table is single-pod only (assignment spec), so the
                    # accounting compiles are skipped here.
                    extra.append("--skip-accounting")
                ok, dt, tail = run_one(arch, shape, mesh, extra,
                                       args.timeout, args.out)
                status = "OK " if ok else "FAIL"
                print(f"{status}  {arch} x {shape} x {mesh} ({dt:.0f}s)")
                if not ok:
                    print("      " + "\n      ".join(tail))
                results.append((arch, shape, mesh, ok, dt))
    n_ok = sum(1 for r in results if r[3])
    print(f"\n{n_ok}/{len(results)} runs succeeded")
    if n_ok < len(results):
        sys.exit(1)


if __name__ == "__main__":
    main()
