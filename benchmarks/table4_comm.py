"""Paper Table 4: transferred data size / trainable params per round,
10 clients, 4/7/10/14 trained VGG16 layers — EXACT accounting on the
paper's exact VGG16 (14,736,714 params).

``--topology`` sweeps the registered federation topologies
(core/topology.py): ``hub`` reproduces the paper's numbers;
``hierarchical`` additionally reports the edge->hub WAN uplink (per-edge
selection unions — strictly below the flat-hub uplink whenever edges
hold >1 client); ``gossip`` shows why partial freezing cannot shrink
peer-exchange traffic.  ``all`` sweeps every topology.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import comm, freezing
from repro.core.masking import build_units_flat, unit_param_counts
from repro.models import paper_models as pm
from .common import csv_row

# paper's Table 4 values for comparison
PAPER = {4: (34.88e6, 133.1e6), 7: (67.92e6, 259.1e6),
         10: (101.3e6, 386.5e6), 14: (147.2e6, 561.6e6)}

CLIENTS = 10
N_EDGES = 2


def _setup():
    p = pm.init_vgg16(jax.random.PRNGKey(0))
    assign = build_units_flat(p, pm.vgg16_units(p))
    return assign, unit_param_counts(assign, p), comm.unit_bytes(assign, p)


def _sel_history(n, rounds, n_units):
    return [np.asarray(freezing.select_clients(
        jax.random.PRNGKey(1000 * n + r), CLIENTS, n_units, n))
        for r in range(rounds)]


def run_hub(fast: bool = True):
    t0 = time.perf_counter()
    assign, counts, ub = _setup()
    rounds = 100 if fast else 500
    print("# Table 4 reproduction (avg over "
          f"{rounds} rounds x {CLIENTS} clients, 4 B/param)")
    print("# layers, avg_trained_params(M), paper_params(M), "
          "avg_uplink(MB), paper_uplink(MB), reduction_vs_full")
    for n in (4, 7, 10, 14):
        tp, tb = [], []
        for sel in _sel_history(n, rounds, assign.n_units):
            tp.append((sel @ counts).sum())
            tb.append(comm.hub_round_bytes(sel, ub)["uplink"])
        mp, mb = np.mean(tp), np.mean(tb)
        red = 1 - mb / (ub.sum() * CLIENTS)
        pp, pb = PAPER[n]
        print(f"{n},{mp/1e6:.2f},{pp/1e6:.2f},{mb/1e6:.1f},{pb/1e6:.1f},"
              f"{red:.3f}")
    dt = (time.perf_counter() - t0) * 1e6 / (4 * rounds)
    csv_row("table4_comm", dt,
            "reduction@25pct~0.71(paper 0.75) @50pct~0.50(paper 0.53)")


def run_hierarchical(fast: bool = True):
    """Beyond-paper: the same selections under edge aggregation.  The
    WAN (edge->hub) term carries only per-edge selection unions, so it
    sits strictly below the flat-hub uplink at the paper's settings."""
    t0 = time.perf_counter()
    assign, counts, ub = _setup()
    rounds = 100 if fast else 500
    mem = comm.edge_membership(CLIENTS, N_EDGES)
    print(f"# hierarchical topology ({N_EDGES} edges x "
          f"{CLIENTS // N_EDGES} clients, avg over {rounds} rounds)")
    print("# layers, flat_hub_uplink(MB), client_edge(MB), "
          "edge_hub_WAN(MB), wan_vs_flat")
    for n in (4, 7, 10, 14):
        flat, lan, wan = [], [], []
        for sel in _sel_history(n, rounds, assign.n_units):
            flat.append(comm.hub_round_bytes(sel, ub)["uplink"])
            d = comm.hierarchical_round_bytes(sel, ub, mem)
            lan.append(d["client_edge_uplink"])
            wan.append(d["edge_hub_uplink"])
        mf, ml, mw = np.mean(flat), np.mean(lan), np.mean(wan)
        assert n == assign.n_units or mw < mf, \
            f"edge->hub WAN {mw} not below flat hub {mf} at {n} layers"
        print(f"{n},{mf/1e6:.1f},{ml/1e6:.1f},{mw/1e6:.1f},{mw/mf:.3f}")
    dt = (time.perf_counter() - t0) * 1e6 / (4 * rounds)
    csv_row("table4_comm_hierarchical", dt,
            f"edge->hub WAN < flat hub at 25%/50% ({N_EDGES} edges)")


def run_gossip(fast: bool = True):
    t0 = time.perf_counter()
    assign, counts, ub = _setup()
    rounds = 20 if fast else 100
    print(f"# gossip topology (ring, {CLIENTS} peers, "
          f"avg over {rounds} rounds)")
    print("# layers, flat_hub_uplink(MB), gossip_peer_bytes(MB), ratio")
    for n in (4, 7, 14):
        flat, peer = [], []
        for sel in _sel_history(n, rounds, assign.n_units):
            flat.append(comm.hub_round_bytes(sel, ub)["uplink"])
            peer.append(comm.gossip_round_bytes(sel, ub)["peer_bytes"])
        mf, mg = np.mean(flat), np.mean(peer)
        print(f"{n},{mf/1e6:.1f},{mg/1e6:.1f},{mg/mf:.2f}")
    dt = (time.perf_counter() - t0) * 1e6 / (3 * rounds)
    csv_row("table4_comm_gossip", dt,
            "freezing does not shrink peer-exchange traffic")


TOPOLOGIES = {"hub": run_hub, "hierarchical": run_hierarchical,
              "gossip": run_gossip}


def run(fast: bool = True, topology: str = "hub"):
    for name in (TOPOLOGIES if topology == "all" else [topology]):
        TOPOLOGIES[name](fast)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="all",
                    choices=sorted(TOPOLOGIES) + ["all"])
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(fast=not args.full, topology=args.topology)
