"""Paper Table 4: transferred data size / trainable params per round,
10 clients, 4/7/10/14 trained VGG16 layers — EXACT accounting on the
paper's exact VGG16 (14,736,714 params)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import comm, freezing
from repro.core.masking import build_units_flat, unit_param_counts
from repro.models import paper_models as pm
from .common import csv_row

# paper's Table 4 values for comparison
PAPER = {4: (34.88e6, 133.1e6), 7: (67.92e6, 259.1e6),
         10: (101.3e6, 386.5e6), 14: (147.2e6, 561.6e6)}


def run(fast: bool = True):
    t0 = time.perf_counter()
    p = pm.init_vgg16(jax.random.PRNGKey(0))
    assign = build_units_flat(p, pm.vgg16_units(p))
    counts = unit_param_counts(assign, p)
    ub = comm.unit_bytes(assign, p)
    rounds = 100 if fast else 500
    clients = 10
    print("# Table 4 reproduction (avg over "
          f"{rounds} rounds x {clients} clients, 4 B/param)")
    print("# layers, avg_trained_params(M), paper_params(M), "
          "avg_uplink(MB), paper_uplink(MB), reduction_vs_full")
    for n in (4, 7, 10, 14):
        tp, tb = [], []
        for r in range(rounds):
            sel = np.asarray(freezing.select_clients(
                jax.random.PRNGKey(1000 * n + r), clients,
                assign.n_units, n))
            tp.append((sel @ counts).sum())
            tb.append((sel @ ub).sum())
        mp, mb = np.mean(tp), np.mean(tb)
        red = 1 - mb / (ub.sum() * clients)
        pp, pb = PAPER[n]
        print(f"{n},{mp/1e6:.2f},{pp/1e6:.2f},{mb/1e6:.1f},{pb/1e6:.1f},"
              f"{red:.3f}")
    dt = (time.perf_counter() - t0) * 1e6 / (4 * rounds)
    csv_row("table4_comm", dt,
            "reduction@25pct~0.71(paper 0.75) @50pct~0.50(paper 0.53)")


if __name__ == "__main__":
    run()
