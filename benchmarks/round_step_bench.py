"""Round-step bench: dense-masked vs packed vs fused (DESIGN.md §7).

Compiles one full federated round step of the stacked-block toy model
(``repro.models.toy`` — scalar + stacked leaf kinds, blocks applied
under ``lax.scan``) at the paper's 25%/50%/75% train fractions and
records, per variant:

* wall time per round step (jitted, warmed up);
* XLA peak temp memory (``compiled.memory_analysis()`` — the live
  buffers of the compiled program, where the packed path's optimizer-
  state savings show up);
* max abs deviation of the new global params vs the dense-masked
  reference (packed is bit-exact; fused is kernel-tolerance).

Writes BENCH_round_step.json — the repo's first bench trajectory
point; EXPERIMENTS.md §Perf records the methodology.  ``--smoke`` is
the CI gate variant (tiny model, fewer reps, same JSON shape).

    PYTHONPATH=src python -m benchmarks.round_step_bench [--smoke]
        [--out BENCH_round_step.json] [--reps 5]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform

import jax
import jax.numpy as jnp

from .common import timed_min
from repro.core.federation import FLConfig, build_round_step
from repro.models.toy import (init_toy_mlp, toy_batches, toy_loss,
                              toy_units)

FULL = dict(n_blocks=16, d=64, hidden=256, out=16,
            n_clients=8, steps=4, batch=8)
SMOKE = dict(n_blocks=8, d=32, hidden=64, out=8,
             n_clients=4, steps=2, batch=4)


def _variant_fl(variant: str, base: FLConfig) -> FLConfig:
    # dense/packed pin fused_agg="off": under the default "auto" a
    # TPU/GPU host would silently fuse the baseline's aggregation too,
    # and every comparison would be against the wrong reference
    if variant == "dense_masked":
        return dataclasses.replace(base, fused_agg="off")
    if variant == "packed":
        return dataclasses.replace(base, packed=True, fused_agg="off")
    if variant == "fused":
        return dataclasses.replace(base, fused_agg="on")
    raise ValueError(variant)


def bench_round_step(*, fractions, reps, cfg) -> dict:
    key = jax.random.PRNGKey(0)
    params = init_toy_mlp(key, n_blocks=cfg["n_blocks"], d=cfg["d"],
                          hidden=cfg["hidden"], out=cfg["out"])
    assign = toy_units(params)
    batches = toy_batches(jax.random.fold_in(key, 1),
                          n_clients=cfg["n_clients"], steps=cfg["steps"],
                          batch=cfg["batch"], d=cfg["d"], out=cfg["out"])
    weights = jnp.ones((cfg["n_clients"],), jnp.float32)
    rk = jax.random.PRNGKey(42)

    out = {}
    for frac in fractions:
        base = FLConfig(n_clients=cfg["n_clients"], train_fraction=frac,
                        strategy="uniform", lr=1e-2)
        row = {}
        ref_params = None
        for variant in ("dense_masked", "packed", "fused"):
            fl = _variant_fl(variant, base)
            step = build_round_step(toy_loss, assign, fl)
            jitted = jax.jit(step)
            compiled = jitted.lower(params, batches, weights, rk).compile()
            mem = compiled.memory_analysis()
            dt, (new_p, _) = timed_min(jitted, params, batches, weights,
                                       rk, reps=reps, warmup=1)
            entry = {
                "wall_s": dt,
                "temp_bytes": int(mem.temp_size_in_bytes),
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
            }
            if variant == "dense_masked":
                ref_params = new_p
            else:
                entry["max_abs_diff_vs_dense"] = float(max(
                    jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))
                    .max()
                    for a, b in zip(jax.tree_util.tree_leaves(ref_params),
                                    jax.tree_util.tree_leaves(new_p))))
            row[variant] = entry
            print(f"frac={frac:.2f} {variant:12s} wall={dt*1e3:8.2f}ms "
                  f"temp={entry['temp_bytes']/1e6:8.2f}MB"
                  + (f" maxdiff={entry.get('max_abs_diff_vs_dense', 0):.1e}"
                     if variant != "dense_masked" else ""))
        row["packed_speedup"] = (row["dense_masked"]["wall_s"]
                                 / row["packed"]["wall_s"])
        row["packed_temp_ratio"] = (row["packed"]["temp_bytes"]
                                    / row["dense_masked"]["temp_bytes"])
        out[f"{frac:.2f}"] = row
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale run (tiny model, fewer reps)")
    ap.add_argument("--out", default="BENCH_round_step.json")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--fractions", type=float, nargs="+",
                    default=[0.25, 0.50, 0.75])
    args = ap.parse_args(argv)

    cfg = SMOKE if args.smoke else FULL
    reps = args.reps if args.reps is not None else (2 if args.smoke else 5)
    results = bench_round_step(fractions=args.fractions, reps=reps, cfg=cfg)

    # correctness gate (this is what CI relies on): packed must stay
    # bit-exact with dense-masked, fused within kernel tolerance
    failures = []
    for frac, row in results.items():
        if row["packed"]["max_abs_diff_vs_dense"] != 0.0:
            failures.append(f"packed diverged at frac={frac}: "
                            f"{row['packed']['max_abs_diff_vs_dense']:.3e}")
        if row["fused"]["max_abs_diff_vs_dense"] > 2e-5:
            failures.append(f"fused diverged at frac={frac}: "
                            f"{row['fused']['max_abs_diff_vs_dense']:.3e}")

    at25 = results.get("0.25")
    report = {
        "bench": "round_step",
        "mode": "smoke" if args.smoke else "full",
        "model": cfg,
        "reps": reps,
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "results": results,
    }
    if at25 is not None:
        report["packed_wins_time_at_25"] = (
            at25["packed"]["wall_s"] < at25["dense_masked"]["wall_s"])
        report["packed_wins_memory_at_25"] = (
            at25["packed"]["temp_bytes"] < at25["dense_masked"]["temp_bytes"])
    report["equivalence_ok"] = not failures
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if at25 is not None:
        print(f"packed @25%: time win={report['packed_wins_time_at_25']} "
              f"memory win={report['packed_wins_memory_at_25']} "
              f"(speedup {at25['packed_speedup']:.2f}x, "
              f"temp ratio {at25['packed_temp_ratio']:.2f})")
    if failures:
        raise SystemExit("equivalence gate FAILED: " + "; ".join(failures))
    return report


if __name__ == "__main__":
    main()
