"""Shared helpers for the paper-table benchmarks.

All federated runs are constructed through the ``Federation`` facade;
``make_vgg_federation``/``make_paper_federation`` return the facade plus
its loader so individual tables only pick settings.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FLConfig, Federation, ModelSpec
from repro.data import FederatedLoader, cifar_like, iid_partition
from repro.models import paper_models as pm


def timed(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def timed_min(fn, *args, reps=5, warmup=1):
    """Best-of-reps wall time: the min is the least load-noise-sensitive
    estimator for a deterministic compiled step (unlike the mean, a
    single preempted rep cannot flip a comparison).  Shared by
    round_step_bench and selection_bench."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def vgg_loss_fn(params, batch):
    return pm.xent_loss(pm.vgg16_apply(params, batch["x"]), batch["y"]), {}


def make_vgg_federation(n_clients: int, n_train_units: int, *,
                        width=0.125, n_data=600, batch_size=8,
                        steps_per_round=2, lr=1e-3, seed=0,
                        data_key=0):
    spec = ModelSpec(
        name="vgg16",
        init_params=functools.partial(pm.init_vgg16, width_mult=width),
        loss_fn=vgg_loss_fn, unit_order=pm.vgg16_units)
    # one draw -> same class prototypes for train and eval (held-out tail)
    n_eval = 256
    x_all, y_all = cifar_like(n_data + n_eval, key=data_key)
    x, y = x_all[:n_data], y_all[:n_data]
    shards = iid_partition(n_data, n_clients, key=data_key + 1)
    loader = FederatedLoader([{"x": x[s], "y": y[s]} for s in shards],
                             batch_size=batch_size,
                             steps_per_round=steps_per_round, key=seed)
    xt, yt = jnp.asarray(x_all[n_data:]), jnp.asarray(y_all[n_data:])

    def eval_acc(p):
        return pm.accuracy(pm.vgg16_apply(p, xt), yt)

    fl = FLConfig(n_clients=n_clients, n_train_units=n_train_units, lr=lr)
    fed = Federation.from_config(spec, fl, data=loader, eval_fn=eval_acc,
                                 seed=seed)
    return fed, loader, fed.assign


def run_rounds(fed: Federation, rounds: int, log_every: int = 0):
    return fed.fit(rounds, log_every=log_every)


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
