"""Paper Fig 4: trained-layer distribution across clients and rounds is
uniform (every layer gets trained, balanced coverage)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import freezing
from .common import csv_row


def run(fast: bool = True):
    t0 = time.perf_counter()
    u, clients = 14, 10
    rounds = 100 if fast else 1000
    print(f"# Fig 4 reproduction: unit selection counts over {rounds} "
          f"rounds x {clients} clients (VGG16's 14 units)")
    print("# setting, min_count, max_count, mean, cv, all_units_covered")
    stats = {}
    for n in (4, 7, 10):
        counts = np.zeros(u)
        for r in range(rounds):
            sel = freezing.select_clients(jax.random.PRNGKey(r * 17 + n),
                                          clients, u, n)
            counts += np.asarray(sel).sum(axis=0)
        cv = counts.std() / counts.mean()
        stats[n] = cv
        print(f"{n}_layers,{counts.min():.0f},{counts.max():.0f},"
              f"{counts.mean():.1f},{cv:.4f},{bool((counts > 0).all())}")
    csv_row("fig4_distribution", (time.perf_counter() - t0) * 1e6,
            f"coverage_cv@7layers={stats[7]:.4f} (uniform => ~0)")


if __name__ == "__main__":
    run()
