import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
# Perf hillclimbing harness (EXPERIMENTS.md §Perf): compile named variants
# of one (arch × shape) pair and report the roofline-term deltas.
#
#   PYTHONPATH=src python -m benchmarks.hillclimb --pair qwen3_train
#
# Each experiment is a hypothesis -> change -> measure cycle; the log
# lines here are pasted into EXPERIMENTS.md §Perf with the napkin math.

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch import roofline, specs
from repro.launch.dryrun import build_jitted, depth_variants, param_counts
from repro.launch.mesh import (make_fl_mesh, make_hier_fl_mesh,
                               make_production_mesh)
from repro.launch.shapes import SHAPES


def measure(arch, shape_name, step_kind, *, layout, mesh=None,
            remat=True, fl_synchronized=False, fl_fraction=0.5,
            fl_topology="hub", cfg_overrides=None, loss_overrides=None,
            label=""):
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = mesh or make_production_mesh()
    fl_clients = cfg.fl_clients_single_pod
    t0 = time.time()

    # full compile -> memory
    j, a, tokens, train, _ = build_jitted(
        cfg, shape, step_kind, mesh, layout, unroll=False, remat=remat,
        fl_synchronized=fl_synchronized, fl_fraction=fl_fraction,
        fl_clients=fl_clients, fl_topology=fl_topology,
        loss_overrides=loss_overrides)
    with mesh:
        comp = j.lower(*a).compile()
    ma = roofline.memory_analysis_terms(comp)

    # accounting compiles at depth 2/3 macros
    cfg1, cfg2, nm = depth_variants(cfg)
    acct = []
    for c in (cfg1, cfg2):
        j2, a2, _, _, _ = build_jitted(
            c, shape, step_kind, mesh, layout, unroll=True, remat=remat,
            fl_synchronized=fl_synchronized, fl_fraction=fl_fraction,
            fl_clients=fl_clients, fl_topology=fl_topology,
            loss_overrides=loss_overrides)
        with mesh:
            comp2 = j2.lower(*a2).compile()
        acct.append((roofline.cost_analysis_terms(comp2),
                     roofline.collective_bytes(comp2.as_text())))
    (ca1, cb1), (ca2, cb2) = acct
    ex = roofline.extrapolate_layers
    flops = ex(ca1["flops"], ca2["flops"], nm)
    hbytes = ex(ca1["bytes"], ca2["bytes"], nm)
    coll = max(ex(cb1["total"], cb2["total"], nm), 0.0)
    terms = roofline.roofline_terms(hlo_flops=flops, hlo_bytes=hbytes,
                                    coll_bytes=coll)
    counts = param_counts(cfg, specs.params_sds(cfg))
    chips = int(np.prod(list(mesh.shape.values())))
    mf = roofline.model_flops(cfg, counts["total"], counts["active"],
                              tokens, train=train)
    rec = dict(label=label, layout=layout, step=step_kind,
               peak_gb=ma["peak_bytes"] / 1e9,
               temp_gb=ma["temp_size_in_bytes"] / 1e9,
               compute_ms=terms["compute_s"] * 1e3,
               memory_ms=terms["memory_s"] * 1e3,
               collective_ms=terms["collective_s"] * 1e3,
               dominant=terms["dominant"],
               coll_gb=coll / 1e9,
               useful=mf / chips / flops if flops else 0.0,
               wall_s=round(time.time() - t0, 1))
    print(f"{label:34s} dom={rec['dominant']:10s} "
          f"comp={rec['compute_ms']:9.1f}ms mem={rec['memory_ms']:9.1f}ms "
          f"coll={rec['collective_ms']:9.1f}ms peak={rec['peak_gb']:7.1f}GB "
          f"useful={rec['useful']:.3f}")
    return rec


PAIRS = {}


def pair(name):
    def deco(fn):
        PAIRS[name] = fn
        return fn
    return deco


@pair("qwen3_train")
def qwen3_train():
    """Small-dense train: TP activation all-reduces vs pure-DP FSDP."""
    out = [measure("qwen3-1.7b", "train_4k", "train", layout="tp",
                   label="baseline tp (paper-era default)")]
    out.append(measure("qwen3-1.7b", "train_4k", "train",
                       layout="fsdp_only",
                       label="fsdp_only (DP-256, params gathered)"))
    out.append(measure("qwen3-1.7b", "train_4k", "train",
                       layout="fsdp_tp",
                       label="fsdp_tp (TP16 + param shard)"))
    out.append(measure("qwen3-1.7b", "train_4k", "train",
                       layout="fsdp_only", remat=False,
                       label="fsdp_only no-remat"))
    return out


@pair("llama4_train")
def llama4_train():
    """400B MoE train: GSPMD scatter dispatch vs explicit shard_map TP
    dispatch (tokens stay put; combine = one psum)."""
    mesh = make_production_mesh()
    out = [measure("llama4-maverick-400b-a17b", "train_4k", "train",
                   layout="fsdp_tp", mesh=mesh,
                   label="baseline fsdp_tp gspmd-dispatch")]
    out.append(measure("llama4-maverick-400b-a17b", "train_4k", "train",
                       layout="fsdp_tp", mesh=mesh,
                       loss_overrides={"moe_mesh": mesh},
                       label="fsdp_tp shard_map TP dispatch"))
    return out


@pair("granite_train")
def granite_train():
    """Small-MoE train: same dispatch comparison."""
    mesh = make_production_mesh()
    out = [measure("granite-moe-1b-a400m", "train_4k", "train",
                   layout="tp", mesh=mesh,
                   label="baseline tp gspmd-dispatch")]
    out.append(measure("granite-moe-1b-a400m", "train_4k", "train",
                       layout="tp", mesh=mesh,
                       loss_overrides={"moe_mesh": mesh},
                       label="tp shard_map TP dispatch"))
    out.append(measure("granite-moe-1b-a400m", "train_4k", "train",
                       layout="fsdp_only", mesh=mesh,
                       loss_overrides={"moe_mesh": mesh},
                       label="fsdp_only + shard_map dispatch"))
    return out


@pair("fl_round")
def fl_round():
    """The paper's technique at pod scale: independent vs synchronized
    selection; 50% vs 25% trained fraction."""
    mesh = make_fl_mesh(16)
    out = []
    for sync, frac, label in [
            (False, 0.5, "fl 50% independent (paper)"),
            (True, 0.5, "fl 50% synchronized (beyond-paper)"),
            (False, 0.25, "fl 25% independent (paper)"),
            (True, 0.25, "fl 25% synchronized (beyond-paper)"),
            (False, 1.0, "fl 100% (conventional FedAvg)")]:
        out.append(measure("qwen3-1.7b", "train_4k", "fl_round",
                           layout="tp", mesh=mesh, fl_synchronized=sync,
                           fl_fraction=frac, label=label))
    return out


@pair("fl_topology")
def fl_topology():
    """Topology plugins at pod scale: the same 50% uniform selection
    compiled under the hub star, hierarchical edge aggregation (the
    edge axis carve-out keeps intra-edge reduces on local interconnect)
    and ring gossip (per-client replicas, no global model)."""
    out = [measure("qwen3-1.7b", "train_4k", "fl_round", layout="tp",
                   mesh=make_fl_mesh(16), fl_topology="hub",
                   label="fl 50% hub (star, paper)")]
    out.append(measure("qwen3-1.7b", "train_4k", "fl_round", layout="tp",
                       mesh=make_hier_fl_mesh(4, 16),
                       fl_topology="hierarchical",
                       label="fl 50% hierarchical (4 edges)"))
    out.append(measure("qwen3-1.7b", "train_4k", "fl_round", layout="tp",
                       mesh=make_fl_mesh(16), fl_topology="gossip",
                       label="fl 50% gossip (ring replicas)"))
    return out


@pair("gemma3_decode")
def gemma3_decode():
    """long_500k decode: the serving pair."""
    out = [measure("gemma3-12b", "long_500k", "decode",
                   layout="fsdp_tp_hd", label="baseline fsdp_tp_hd")]
    out.append(measure("gemma3-12b", "long_500k", "decode",
                       layout="tp_hd", label="tp_hd (no fsdp)"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=sorted(PAIRS))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    recs = PAIRS[args.pair]()
    if args.out:
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1)




# ---------------------------------------------------------------------------
# fl_static: the paper's saving, realized at pod scale.
#
# Finding from pair "fl_round": with TRACED masks (paper-faithful dynamic
# per-round selection inside one compiled round) every variant lowers to
# the SAME program — XLA cannot dead-code-eliminate data-dependent
# freezing, so FLOPs, collectives and memory are identical from 25% to
# 100% trained.  The saving exists only when the selection is STATIC
# (compile-time): frozen layers' weight-gradient einsums, their grad
# all-reduce and their optimizer states all disappear.  A production
# deployment recompiles per round (or caches a few mask patterns) —
# synchronized selection (one subset per round) makes that feasible:
# independent per-client subsets would need C different programs.
# ---------------------------------------------------------------------------

def _split_by_units(assign, params, sel):
    """Split params into (trainable_subtree, merge_fn) for static sel."""
    import numpy as _np
    import jax.numpy as _jnp
    from repro.core.masking import _is_leafunit
    from repro.common import pytree as _pt

    leaf_units = jax.tree_util.tree_leaves(assign.leaf_units,
                                           is_leaf=_is_leafunit)
    flat = list(_pt.flatten_with_paths(params))
    plan = []                      # (path, kind, idx or None)
    trainable = {}
    for (path, leaf), lu in zip(flat, leaf_units):
        if lu.kind == "scalar":
            if sel[lu.base]:
                plan.append((path, "whole", None))
                trainable[path] = leaf
        else:
            nm = leaf.shape[0]
            idx = [m for m in range(nm) if sel[lu.base + lu.stride * m]]
            if idx:
                plan.append((path, "rows", tuple(idx)))
                trainable[path] = leaf[_np.asarray(idx)] \
                    if not isinstance(leaf, jax.ShapeDtypeStruct) else \
                    jax.ShapeDtypeStruct((len(idx),) + leaf.shape[1:],
                                         leaf.dtype)

    def merge(base, train):
        flat_base = dict(_pt.flatten_with_paths(base))
        for path, kind, idx in plan:
            if kind == "whole":
                flat_base[path] = train[path]
            else:
                flat_base[path] = flat_base[path].at[
                    _jnp.asarray(idx)].set(train[path])
        return _pt.tree_map_with_path(lambda p, x: flat_base[p], base)

    return trainable, merge


@pair("fl_static")
def fl_static():
    """Static (compile-time) layer selection on the pod: measures the
    FLOP / collective / optimizer-memory saving the paper's technique
    yields once selection is baked into the program."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.masking import build_units_zoo
    from repro.launch.steps import default_loss_kwargs
    from repro.models import get_model
    from repro.optim.masked import adam_init, adam_step
    import numpy as _np

    cfg = get_config("qwen3-1.7b")
    mesh = make_production_mesh()
    shape = SHAPES["train_4k"]
    model = get_model(cfg)
    params = specs.params_sds(cfg)
    assign = build_units_zoo(cfg, params)
    kw = default_loss_kwargs(cfg, remat=True, unroll=True)
    batch = specs.batch_specs(cfg, shape)
    b_sh = specs.batch_shardings(cfg, shape, mesh, "tp")
    counts = param_counts(cfg, params)
    out = []
    rng = _np.random.default_rng(0)
    for frac, label in [(1.0, "static 100% (full training)"),
                        (0.5, "static 50% selected"),
                        (0.25, "static 25% selected")]:
        n_train = max(1, round(assign.n_units * frac))
        sel = _np.zeros(assign.n_units, bool)
        sel[rng.choice(assign.n_units, n_train, replace=False)] = True
        train_sds, merge = _split_by_units(assign, params, sel)

        def step2(params_base_, train_p, opt, batch, merge=merge):
            def loss(tp):
                return model.loss_fn(merge(params_base_, tp), batch, **kw)
            (l, _), g = jax.value_and_grad(loss, has_aux=True)(train_p)
            train_p, opt = adam_step(g, opt, train_p, lr=3e-4)
            return train_p, opt, l

        p_sh_full = specs.param_shardings(cfg, mesh, params, "tp")
        t_sh = specs.param_shardings(cfg, mesh, train_sds, "tp")
        opt = jax.eval_shape(adam_init, train_sds)
        opt_sh = specs.opt_shardings(t_sh, mesh)
        rep = NamedSharding(mesh, P())
        jitted = jax.jit(step2, in_shardings=(p_sh_full, t_sh, opt_sh, b_sh),
                         out_shardings=(t_sh, opt_sh, rep))
        import time as _time
        t0 = _time.time()
        with mesh:
            comp = jitted.lower(params, train_sds, opt, batch).compile()
        ca = roofline.cost_analysis_terms(comp)
        cb = roofline.collective_bytes(comp.as_text())
        ma = roofline.memory_analysis_terms(comp)
        terms = roofline.roofline_terms(hlo_flops=ca["flops"],
                                        hlo_bytes=ca["bytes"],
                                        coll_bytes=cb["total"])
        import numpy as np2
        from repro.core.masking import unit_param_counts
        trained_params = float(unit_param_counts(assign, params)[sel].sum())
        rec = dict(label=label, frac=frac,
                   trained_params=trained_params,
                   compute_ms=terms["compute_s"] * 1e3,
                   memory_ms=terms["memory_s"] * 1e3,
                   collective_ms=terms["collective_s"] * 1e3,
                   coll_gb=cb["total"] / 1e9,
                   dominant=terms["dominant"],
                   arg_gb=ma["argument_size_in_bytes"] / 1e9,
                   temp_gb=ma["temp_size_in_bytes"] / 1e9,
                   wall_s=round(_time.time() - t0, 1))
        print(f"{label:32s} dom={rec['dominant']:10s} "
              f"comp={rec['compute_ms']:8.1f}ms mem={rec['memory_ms']:8.1f}ms"
              f" coll={rec['collective_ms']:8.1f}ms arg={rec['arg_gb']:.2f}GB"
              f" temp={rec['temp_gb']:.1f}GB trained={trained_params/1e9:.2f}B")
        out.append(rec)
    return out




@pair("fl_static_unstacked")
def fl_static_unstacked():
    """Iteration on fl_static's refutation: same static selection but
    with per-layer (UNSTACKED) params so frozen layers' dW einsums are
    DCE-able.  Hypothesis: backward dW is ~1/3 of train FLOPs; freezing
    half the layers should cut ~17% of total FLOPs and the frozen
    layers' grad all-reduce."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.steps import default_loss_kwargs
    from repro.models import layers as L
    from repro.models import transformer as T
    from repro.models.attention import attend
    from repro.optim.masked import adam_init, adam_step
    import numpy as _np

    cfg = get_config("qwen3-1.7b")
    mesh = make_production_mesh()
    shape = SHAPES["train_4k"]
    params = specs.params_sds(cfg)
    spec_sub = T.block_layout(cfg)[0]
    nm = T.n_macro(cfg)
    kw = {}
    batch = specs.batch_specs(cfg, shape)
    b_sh = specs.batch_shardings(cfg, shape, mesh, "tp")

    def row(leaf, m):
        return jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype) \
            if isinstance(leaf, jax.ShapeDtypeStruct) else leaf[m]

    def split(sel_layers):
        blocks = params["blocks"]["sub0"]
        per_layer = [jax.tree_util.tree_map(lambda x, m=m: row(x, m), blocks)
                     for m in range(nm)]
        top = {k: params[k] for k in params if k != "blocks"}
        trainable = {f"layer{m}": per_layer[m] for m in sel_layers}
        trainable.update(top)      # embed/head/norm always trained here
        frozen = {f"layer{m}": per_layer[m] for m in range(nm)
                  if m not in sel_layers}
        return trainable, frozen

    def forward_loss(trainable, frozen, batch):
        rope = L.rope_freqs(cfg.head_dim, cfg.rope_pct, cfg.rope_theta)
        x = L.embed_tokens(trainable["embed"], batch["tokens"])
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        for m in range(nm):
            blk = trainable.get(f"layer{m}", frozen.get(f"layer{m}"))
            def one(x, blk=blk):
                x, _, _ = T._apply_sub(cfg, blk, spec_sub, x, positions,
                                       rope, "chunked", 1024)
                return x
            x = jax.checkpoint(one)(x)
        x = L.apply_norm(trainable["final_norm"], x)
        logits = L.logits_head(trainable, x, cfg.tie_embeddings)
        return L.softmax_xent(logits, batch["labels"])

    rng = _np.random.default_rng(0)
    out = []
    for frac, label in [(1.0, "unstacked 100%"), (0.5, "unstacked 50%"),
                        (0.25, "unstacked 25%")]:
        n_sel = max(1, round(nm * frac))
        sel_layers = tuple(sorted(rng.choice(nm, n_sel, replace=False)))
        trainable, frozen = split(sel_layers)

        def step(trainable, frozen, opt, batch):
            l, g = jax.value_and_grad(forward_loss)(trainable, frozen,
                                                    batch)
            trainable, opt = adam_step(g, opt, trainable, lr=3e-4)
            return trainable, opt, l

        t_sh = jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, P(*([None] * x.ndim))), trainable)
        # reuse rule engine per-leaf (paths differ; fall back replicated
        # for simplicity of this probe — collectives of interest are the
        # activation all-reduces + grad reduce over data, still present)
        from repro.sharding import spec_for
        def sh(tree, prefix):
            return {k: (jax.tree_util.tree_map_with_path(
                lambda kp, x: NamedSharding(mesh, spec_for(
                    "blocks/sub0/" + "/".join(str(getattr(p, "key", p))
                                              for p in kp),
                    x.shape, "tp", mesh)), v)
                if k.startswith("layer") else jax.tree_util.tree_map_with_path(
                    lambda kp, x: NamedSharding(mesh, spec_for(
                        k + "/" + "/".join(str(getattr(p, "key", p))
                                           for p in kp),
                        x.shape, "tp", mesh)), v))
                for k, v in tree.items()}
        t_sh = sh(trainable, "t")
        f_sh = sh(frozen, "f")
        opt = jax.eval_shape(adam_init, trainable)
        opt_sh = specs.opt_shardings(t_sh, mesh)
        rep = NamedSharding(mesh, P())
        jitted = jax.jit(step, in_shardings=(t_sh, f_sh, opt_sh, b_sh),
                         out_shardings=(t_sh, opt_sh, rep))
        import time as _time
        t0 = _time.time()
        with mesh:
            comp = jitted.lower(trainable, frozen, opt, batch).compile()
        ca = roofline.cost_analysis_terms(comp)
        cb = roofline.collective_bytes(comp.as_text())
        ma = roofline.memory_analysis_terms(comp)
        terms = roofline.roofline_terms(hlo_flops=ca["flops"],
                                        hlo_bytes=ca["bytes"],
                                        coll_bytes=cb["total"])
        rec = dict(label=label, frac=frac,
                   compute_ms=terms["compute_s"] * 1e3,
                   memory_ms=terms["memory_s"] * 1e3,
                   collective_ms=terms["collective_s"] * 1e3,
                   arg_gb=ma["argument_size_in_bytes"] / 1e9,
                   temp_gb=ma["temp_size_in_bytes"] / 1e9,
                   wall_s=round(_time.time() - t0, 1))
        print(f"{label:20s} comp={rec['compute_ms']:8.1f}ms "
              f"mem={rec['memory_ms']:8.1f}ms coll={rec['collective_ms']:8.1f}ms"
              f" arg={rec['arg_gb']:.2f}GB temp={rec['temp_gb']:.1f}GB")
        out.append(rec)
    return out


if __name__ == "__main__":
    main()
