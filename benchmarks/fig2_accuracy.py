"""Paper Fig 2: global-model accuracy vs number of trained layers
(VGG16-family on synthetic CIFAR, 10 clients, IID).

The trend claim reproduced: >=50% of layers -> accuracy within a small
gap of full-model FL; 4 layers converges slower/lower."""
from __future__ import annotations

import time

import numpy as np

from .common import csv_row, make_vgg_federation, run_rounds


def run(fast: bool = True):
    t0 = time.perf_counter()
    rounds = 6 if fast else 40
    clients = 4 if fast else 10
    n_data = 400 if fast else 4000
    layer_settings = (4, 7, 14) if fast else (4, 7, 10, 14)
    print(f"# Fig 2 reproduction ({clients} clients, {rounds} rounds, "
          f"synthetic CIFAR stand-in)")
    print("# layers, final_acc, final_loss, acc_history")
    finals = {}
    for n in layer_settings:
        srv, loader, _ = make_vgg_federation(clients, n, n_data=n_data,
                                             width=0.125, lr=3e-3,
                                             steps_per_round=3,
                                             batch_size=16)
        hist = run_rounds(srv, rounds)
        accs = [h.eval_metric for h in hist]
        finals[n] = accs[-1]
        print(f"{n},{accs[-1]:.3f},{hist[-1].loss:.3f},"
              + "|".join(f"{a:.3f}" for a in accs))
    full = finals[max(layer_settings)]
    half = finals[7]
    gap = full - half
    csv_row("fig2_accuracy", (time.perf_counter() - t0) * 1e6,
            f"half_vs_full_gap={gap:.3f} (paper: ~0.013)")
    return finals


if __name__ == "__main__":
    run()
