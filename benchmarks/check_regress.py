"""Benchmark regression gate: smoke BENCH_*.json vs committed baselines.

The smoke benches are seeded and CPU-deterministic, so their
*deterministic* metrics — byte columns, quarantine counts, accuracy
trajectories, the boolean acceptance gates — must reproduce exactly
run-over-run.  This gate diffs every freshly-written smoke report under
``results/`` against the committed baseline in
``benchmarks/baselines/`` and fails ``./test.sh`` (and CI) on any
drift, so a PR that silently shifts the byte accounting, breaks a
bitwise gate or changes a seeded trajectory is caught by tier-1
instead of by a human reading JSON.

Timing/host-dependent keys (wall seconds, draw latencies, RSS,
platform strings) are skipped by name pattern; boolean gates may only
degrade (a baseline ``false`` that becomes ``true`` is an improvement,
not a regression).  New keys in fresh reports are allowed — adding
metrics is not a regression; dropping them is.

    PYTHONPATH=src python -m benchmarks.check_regress          # gate
    PYTHONPATH=src python -m benchmarks.check_regress --update # reseed

``--update`` copies the current results over the baselines — run it
(and commit the diff) when a change legitimately moves a metric.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import re
import shutil
import sys

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")
RESULTS_DIR = "results"

# host/timing noise: never compared (matched against the dot-joined
# key path, case-insensitive)
SKIP = re.compile(
    r"(seconds|_sec\b|_ms\b|_time|time_|rss|per_s|wall_s|round_s|"
    r"latency_|speedup|throughput|sublinear|sampler_ok|"
    r"platform|backend|\bjax\b|hostname|timestamp)", re.I)
# boolean gates: true -> false is a regression, false -> true is not
GATE = re.compile(r"(_ok$|_equal$|^ok$|bitwise|^finite$|\bexact\b)", re.I)


def _diff(base, new, path, out):
    key = ".".join(path)
    if SKIP.search(key):
        return
    if isinstance(base, dict):
        if not isinstance(new, dict):
            out.append(f"{key}: dict became {type(new).__name__}")
            return
        for k, bv in base.items():
            if k not in new:
                out.append(f"{key}.{k}: metric disappeared")
            else:
                _diff(bv, new[k], path + [str(k)], out)
        return
    if isinstance(base, list):
        if not isinstance(new, list) or len(new) != len(base):
            out.append(f"{key}: list {len(base)} -> "
                       f"{len(new) if isinstance(new, list) else new!r}")
            return
        for i, (bv, nv) in enumerate(zip(base, new)):
            _diff(bv, nv, path + [str(i)], out)
        return
    if isinstance(base, bool) or GATE.search(key):
        if bool(base) and not bool(new):
            out.append(f"{key}: gate regressed {base!r} -> {new!r}")
        return
    if isinstance(base, (int, float)) and isinstance(new, (int, float)):
        # deterministic metrics reproduce exactly; the tolerance only
        # absorbs json float round-trip noise
        if not math.isclose(base, new, rel_tol=1e-9, abs_tol=1e-12):
            out.append(f"{key}: {base!r} -> {new!r}")
        return
    if base != new:
        out.append(f"{key}: {base!r} -> {new!r}")


def check_file(name, baseline_dir, results_dir):
    """Diff one report; returns (status, regressions)."""
    res_path = os.path.join(results_dir, name)
    if not os.path.exists(res_path):
        return "missing", []
    with open(os.path.join(baseline_dir, name)) as f:
        base = json.load(f)
    with open(res_path) as f:
        new = json.load(f)
    out = []
    _diff(base, new, [name], out)
    return ("regressed" if out else "ok"), out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--results-dir", default=RESULTS_DIR)
    ap.add_argument("--update", action="store_true",
                    help="reseed baselines from current results")
    ap.add_argument("--strict", action="store_true",
                    help="fail when a baselined report was not produced "
                         "this run (default: skip it)")
    args = ap.parse_args(argv)

    names = sorted(n for n in os.listdir(args.baseline_dir)
                   if n.startswith("BENCH_") and n.endswith(".json"))
    if not names:
        raise SystemExit(f"no baselines under {args.baseline_dir}")

    if args.update:
        for name in names:
            src = os.path.join(args.results_dir, name)
            if os.path.exists(src):
                shutil.copyfile(src,
                                os.path.join(args.baseline_dir, name))
                print(f"reseeded {name}")
            else:
                print(f"skipped {name} (no fresh result)")
        return 0

    failed = []
    for name in names:
        status, out = check_file(name, args.baseline_dir,
                                 args.results_dir)
        if status == "missing":
            print(f"SKIP {name} (not produced this run)")
            if args.strict:
                failed.append(f"{name}: report not produced")
        elif status == "ok":
            print(f"OK   {name}")
        else:
            print(f"FAIL {name}:")
            for line in out:
                print(f"  {line}")
            failed.extend(out)
    if failed:
        print(f"\n{len(failed)} regression(s) vs committed baselines — "
              "if intentional, reseed with: python -m "
              "benchmarks.check_regress --update (and commit the diff)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
