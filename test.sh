#!/usr/bin/env bash
# Tier-1 gate + dry-run smoke.
#
#   ./test.sh              # pytest (8 fake CPU devices) + dryrun smoke
#   ./test.sh --fast       # pytest only
#   ./test.sh -k pattern   # extra args forwarded to pytest
#
# XLA_FLAGS forces 8 host devices so the multi-device pjit paths are
# exercised on CPU; launch/dryrun subprocesses override it themselves
# (they need 512).
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"

FAST=0
ARGS=()
for a in "$@"; do
  if [[ "$a" == "--fast" ]]; then FAST=1; else ARGS+=("$a"); fi
done

python -m pytest -q "${ARGS[@]+"${ARGS[@]}"}"

# chaos harness smoke (runs in --fast too): zero-rate chaos bitwise ==
# clean, kill+resume bitwise == uninterrupted, quarantine == plan
python -m benchmarks.faults_bench --smoke --out results/BENCH_faults_smoke.json

if [[ "$FAST" == "0" ]]; then
  # one representative (arch x shape x mesh) dry-run as a smoke gate
  python -m benchmarks.run_dryrun_all --mesh single \
    --archs qwen3-1.7b --shapes train_4k --timeout 900 \
    --out results/dryrun-smoke
  # serving engine smoke: continuous == static streams, one decode compile
  python -m benchmarks.serve_bench --smoke --out results/BENCH_serve_smoke.json
  # cohort engine smoke: chunked == vmapped bitwise + fleet-scale RSS rows
  python -m benchmarks.cohort_bench --smoke --out results/BENCH_cohort_smoke.json
fi
