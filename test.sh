#!/usr/bin/env bash
# Tier-1 gate + static analysis + dry-run smoke.
#
#   ./test.sh              # pytest (8 fake CPU devices) + analyzer + smokes
#   ./test.sh --fast       # pytest + analyzer only
#   ./test.sh --analyze    # static-analysis gate only (lint + jaxpr trace)
#   ./test.sh -k pattern   # extra args forwarded to pytest
#
# XLA_FLAGS forces 8 host devices so the multi-device pjit paths are
# exercised on CPU; launch/dryrun subprocesses override it themselves
# (they need 512).
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"

FAST=0
ANALYZE_ONLY=0
ARGS=()
for a in "$@"; do
  case "$a" in
    --fast)    FAST=1 ;;
    --analyze) ANALYZE_ONLY=1 ;;
    *)         ARGS+=("$a") ;;
  esac
done

# static-analysis gate (DESIGN.md §15): AST lint + jaxpr contract
# checks; exits non-zero on any non-suppressed finding
if [[ "$ANALYZE_ONLY" == "1" ]]; then
  exec python -m repro.analysis.cli --report results/analysis.json
fi

python -m pytest -q "${ARGS[@]+"${ARGS[@]}"}"

python -m repro.analysis.cli --report results/analysis.json

# chaos harness smoke (runs in --fast too): zero-rate chaos bitwise ==
# clean, kill+resume bitwise == uninterrupted, quarantine == plan
python -m benchmarks.faults_bench --smoke --out results/BENCH_faults_smoke.json

if [[ "$FAST" == "0" ]]; then
  # one representative (arch x shape x mesh) dry-run as a smoke gate
  python -m benchmarks.run_dryrun_all --mesh single \
    --archs qwen3-1.7b --shapes train_4k --timeout 900 \
    --out results/dryrun-smoke
  # serving engine smoke: continuous == static streams, one decode compile
  python -m benchmarks.serve_bench --smoke --out results/BENCH_serve_smoke.json
  # cohort engine smoke: chunked == vmapped bitwise + fleet-scale RSS rows
  python -m benchmarks.cohort_bench --smoke --out results/BENCH_cohort_smoke.json
  # uplink codec smoke: codec "none" bitwise on all three round paths,
  # claimed bytes == encoded wire bytes, qint8 >= 3.5x byte cut
  python -m benchmarks.codec_bench --smoke --out results/BENCH_codec_smoke.json
fi

# bench regression gate: smoke reports produced this run must reproduce
# the committed baselines exactly on deterministic metrics (timing and
# host keys are skipped); reseed intentionally-moved metrics with
#   python -m benchmarks.check_regress --update
python -m benchmarks.check_regress
